"""Preempt → evict-to-host → resume: the slot-state manager contract.

The acceptance property: an evicted request resumes the exact token
trajectory (and, when the slot is re-granted without delay, the exact
tick stamps) it would have produced uninterrupted — across KV-ring
(dense), rwkv-recurrent, and hybrid ssd/conv (hymba) cache pytrees.
Plus the EDF end-to-end behaviour: a tighter deadline evicts a running
request, runs, and the victim still completes bit-exactly."""

import jax
import pytest

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config

NOSH = Sharder(None, {})


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(setup, **kw):
    cfg, model, params = setup
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    return ServingEngine(model, params, NOSH, **kw)


def _solo_output(model, params, prompt, max_new, max_len=32):
    eng = ServingEngine(model, params, NOSH, max_batch=1, max_len=max_len)
    r = eng.submit(list(prompt), max_new_tokens=max_new)
    eng.run()
    return r.output


# ------------------------------------------------- bit-exact resume property


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "qwen2.5-14b", "hymba-1.5b"])
def test_preempt_evict_resume_bit_exact(arch):
    """Evict a mid-decode request to host, serve an unrelated request
    through the same slot (clobbering the device state the victim used),
    resume — the victim's tokens are bit-identical to an uninterrupted
    run.  Covers KV rings, rwkv wkv/shift state, and hymba's ssd/conv
    hybrid via the same gather/scatter contract."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 9, 3, 7, 2]
    base = _solo_output(model, params, prompt, 10)

    eng = ServingEngine(model, params, NOSH, max_batch=1, max_len=32)
    a = eng.submit(list(prompt), max_new_tokens=10)
    for _ in range(3):
        eng.step()
    assert not a.done and len(a.output) >= 3
    n_at_evict = len(a.output)
    eng.preempt(0)
    assert a.saved is not None and a.n_preempts == 1
    held = eng.scheduler.queue.popleft()     # keep A aside while B runs
    assert held is a
    b = eng.submit([2, 4, 6, 8], max_new_tokens=6)
    eng.run()
    assert b.done and not a.done             # B used (and clobbered) slot 0
    eng.scheduler.requeue_front(a)
    eng.run()
    assert a.done and a.saved is None
    assert a.output == base                  # bit-exact across the round trip
    assert len(a.t_resumes) == 1
    assert eng.stats()["preemptions"] == 1
    assert eng.stats()["resumes"] == 1
    assert eng.stats()["evicted_tokens"] == n_at_evict


def test_immediate_resume_is_schedule_noop(setup):
    """Preempt between steps and let the scheduler re-grant the slot on
    the very next step: tokens AND tick stamps of every request match the
    uninterrupted run exactly (stochastic sampling included — same slot,
    same tick sequence, same key stream)."""
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8]]
    sampler = SamplerConfig(temperature=0.8, top_k=5)

    def serve(preempt_at):
        eng = _engine(setup, seed=7, sampler=sampler)
        reqs = [eng.submit(list(p), max_new_tokens=8) for p in prompts]
        for k in range(3):
            eng.step()
            if k == preempt_at:
                eng.preempt(0)
        eng.run()
        return [(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done)
                for r in reqs], eng.util_history

    uninterrupted = serve(preempt_at=None)
    interrupted = serve(preempt_at=1)
    assert interrupted == uninterrupted


def test_resume_lands_in_a_different_slot(setup):
    """Slot identity is not part of the saved state: a request evicted
    from slot 0 resumes bit-exactly from whichever slot frees first."""
    cfg, model, params = setup
    prompt = [3, 1, 4, 1, 5]
    base = _solo_output(model, params, prompt, 12)

    eng = _engine(setup)                       # max_batch=2, greedy
    a = eng.submit(list(prompt), max_new_tokens=12)
    b = eng.submit([2, 7, 1, 8], max_new_tokens=6)
    for _ in range(2):
        eng.step()                             # a -> slot 0, b -> slot 1
    assert eng.sm.slots[0] is a and eng.sm.slots[1] is b
    eng.preempt(0)
    held = eng.scheduler.queue.popleft()       # hold A; C takes slot 0
    c = eng.submit([9, 9, 2], max_new_tokens=12)
    while not b.done:
        eng.step()
    eng.scheduler.requeue_front(held)
    eng.step()
    assert eng.sm.slots[1] is a                # resumed into B's old slot
    eng.run()
    assert a.done and c.done
    assert a.output == base


def test_preempt_validates_slot(setup):
    eng = _engine(setup)
    with pytest.raises(ValueError, match="empty"):
        eng.preempt(0)


# ----------------------------------------------------------- EDF end-to-end


def test_edf_preempts_running_for_tighter_deadline(setup):
    """max_batch=1 under preemptive EDF: a late-deadline request is
    evicted the moment a strictly tighter deadline arrives, the urgent
    request runs to completion first, and the victim still finishes
    bit-exactly."""
    cfg, model, params = setup
    slow_prompt, fast_prompt = [5, 9, 3, 7, 2], [8, 6, 4]
    base_slow = _solo_output(model, params, slow_prompt, 10)
    base_fast = _solo_output(model, params, fast_prompt, 4)

    eng = ServingEngine(model, params, NOSH, max_batch=1, max_len=32,
                        policy="edf", preempt=True)
    slow = eng.submit(list(slow_prompt), max_new_tokens=10, deadline=500.0)
    for _ in range(3):
        eng.step()
    urgent = eng.submit(list(fast_prompt), max_new_tokens=4, deadline=10.0)
    eng.run()
    assert slow.done and urgent.done
    assert slow.n_preempts == 1 and urgent.n_preempts == 0
    assert urgent.t_done < slow.t_done       # the tight deadline went first
    assert urgent.t_admit is not None and urgent.t_admit <= urgent.t_submit + 1
    assert slow.output == base_slow          # bit-exact despite the eviction
    assert urgent.output == base_fast
    s = eng.stats()
    assert s["preemptions"] == 1 and s["resumes"] == 1


def test_edf_without_preempt_never_evicts(setup):
    cfg, model, params = setup
    eng = ServingEngine(model, params, NOSH, max_batch=1, max_len=32,
                        policy="edf", preempt=False)
    slow = eng.submit([5, 9, 3], max_new_tokens=8, deadline=500.0)
    eng.step()
    urgent = eng.submit([8, 6], max_new_tokens=2, deadline=5.0)
    eng.run()
    assert slow.done and urgent.done
    assert eng.stats()["preemptions"] == 0
    assert slow.t_done < urgent.t_done       # ran to completion undisturbed


def test_deadline_flows_from_submit_and_reset_clears_counters(setup):
    eng = _engine(setup)
    r = eng.submit([1, 2, 3], max_new_tokens=2, deadline=42.0)
    assert r.deadline == 42.0
    eng.run()
    eng.metrics["engine.preemptions"].inc(3)   # simulate history, then reset
    eng.reset_telemetry()
    s = eng.stats()
    assert s["preemptions"] == 0 and s["resumes"] == 0
    assert s["evicted_tokens"] == 0
