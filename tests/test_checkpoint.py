"""Checkpoint manager: roundtrip, atomicity, retention, async, restart
equivalence of the full train loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.optim.adamw import abstract_state, init_state
from repro.testing import reduced_config, smoke_shape
from repro.train.loop import TrainLoopConfig, train


def _state():
    model = build_model(reduced_config("granite-moe-1b-a400m"))
    return model, init_state(model.param_specs(), jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, extra={"data_step": 7})
    restored = mgr.restore(abstract_state(model.param_specs()))
    chk = jax.tree.map(lambda a, b: np.array_equal(np.asarray(a),
                                                   np.asarray(b)),
                       state, restored)
    assert all(jax.tree.leaves(chk))
    assert mgr.manifest(7)["extra"]["data_step"] == 7


def test_async_save_then_restore(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_retention_keeps_newest(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    assert mgr.all_steps() == [3, 4]


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"x": jnp.arange(4)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_latest_picks_max(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in (3, 11, 7):
        mgr.save(s, {"x": jnp.ones(2) * s})
    out = mgr.restore({"x": jnp.zeros(2)})
    assert float(out["x"][0]) == 11


@pytest.mark.slow
def test_train_restart_equivalence(tmp_path, nosharder):
    """Training 6 steps straight == training 3, 'crashing', resuming 3."""
    arch = "hymba-1.5b"
    shape = smoke_shape("train", seq=16, batch=2)

    model = build_model(reduced_config(arch))
    base = TrainLoopConfig(total_steps=6, checkpoint_every=100,
                           checkpoint_dir=None, log_every=100, seed=5)
    _, hist_straight = train(model, shape, nosharder, base)

    d = str(tmp_path / "ck")
    first = TrainLoopConfig(total_steps=3, checkpoint_every=3,
                            checkpoint_dir=d, log_every=100, seed=5,
                            async_checkpoint=False)
    train(build_model(reduced_config(arch)), shape, nosharder, first)
    second = TrainLoopConfig(total_steps=6, checkpoint_every=3,
                             checkpoint_dir=d, log_every=100, seed=5,
                             async_checkpoint=False)
    _, hist_resumed = train(build_model(reduced_config(arch)), shape,
                            nosharder, second)
    np.testing.assert_allclose(hist_straight[-1]["loss"],
                               hist_resumed[-1]["loss"], rtol=1e-4)
