"""Checkpoint manager: roundtrip, atomicity, retention, async, restart
equivalence of the full train loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.optim.adamw import abstract_state, init_state
from repro.testing import reduced_config, smoke_shape
from repro.train.loop import TrainLoopConfig, train


def _state():
    model = build_model(reduced_config("granite-moe-1b-a400m"))
    return model, init_state(model.param_specs(), jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, extra={"data_step": 7})
    restored = mgr.restore(abstract_state(model.param_specs()))
    chk = jax.tree.map(lambda a, b: np.array_equal(np.asarray(a),
                                                   np.asarray(b)),
                       state, restored)
    assert all(jax.tree.leaves(chk))
    assert mgr.manifest(7)["extra"]["data_step"] == 7


def test_async_save_then_restore(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_retention_keeps_newest(tmp_path):
    model, state = _state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(3) * s})
    assert mgr.all_steps() == [3, 4]


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"x": jnp.arange(4)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_latest_picks_max(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in (3, 11, 7):
        mgr.save(s, {"x": jnp.ones(2) * s})
    out = mgr.restore({"x": jnp.zeros(2)})
    assert float(out["x"][0]) == 11


@pytest.mark.slow
def test_train_restart_equivalence(tmp_path, nosharder):
    """Training 6 steps straight == training 3, 'crashing', resuming 3."""
    arch = "hymba-1.5b"
    shape = smoke_shape("train", seq=16, batch=2)

    model = build_model(reduced_config(arch))
    base = TrainLoopConfig(total_steps=6, checkpoint_every=100,
                           checkpoint_dir=None, log_every=100, seed=5)
    _, hist_straight = train(model, shape, nosharder, base)

    d = str(tmp_path / "ck")
    first = TrainLoopConfig(total_steps=3, checkpoint_every=3,
                            checkpoint_dir=d, log_every=100, seed=5,
                            async_checkpoint=False)
    train(build_model(reduced_config(arch)), shape, nosharder, first)
    second = TrainLoopConfig(total_steps=6, checkpoint_every=3,
                             checkpoint_dir=d, log_every=100, seed=5,
                             async_checkpoint=False)
    _, hist_resumed = train(build_model(reduced_config(arch)), shape,
                            nosharder, second)
    np.testing.assert_allclose(hist_straight[-1]["loss"],
                               hist_resumed[-1]["loss"], rtol=1e-4)


# ---------------------------------------------------------------------------
# Partial / missing checkpoints must fail with ONE clear error up front
# (PR 8): a crash-restart that lands on a damaged step should name every
# absent piece, not die on a bare FileNotFoundError mid-rebuild.
# ---------------------------------------------------------------------------


def test_restore_missing_step_lists_available(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"x": jnp.ones(2)})
    with pytest.raises(FileNotFoundError, match=r"step 42 not found.*\[3\]"):
        mgr.restore({"x": jnp.zeros(2)}, step=42)


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="no checkpoints under"):
        mgr.restore({"x": jnp.zeros(2)})
    with pytest.raises(FileNotFoundError, match="no steps saved yet"):
        mgr.restore({"x": jnp.zeros(2)}, step=0)


def test_restore_partial_step_names_missing_leaves(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(2), "y": jnp.zeros(3)})
    os.remove(os.path.join(tmp_path, "step_0000000001", "x.npy"))
    with pytest.raises(FileNotFoundError,
                       match=r"incomplete.*missing on disk.*'x'"):
        mgr.restore({"x": jnp.zeros(2), "y": jnp.zeros(3)}, step=1)


def test_restore_missing_manifest_explains(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(2)})
    os.remove(os.path.join(tmp_path, "step_0000000001", "manifest.json"))
    with pytest.raises(FileNotFoundError, match="no manifest.json"):
        mgr.restore({"x": jnp.zeros(2)}, step=1)
    with pytest.raises(FileNotFoundError, match="no manifest.json"):
        mgr.manifest(1)


def test_restore_template_wants_unsaved_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(2)})
    with pytest.raises(FileNotFoundError,
                       match="manifest never saved.*'z'"):
        mgr.restore({"x": jnp.zeros(2), "z": jnp.zeros(1)}, step=1)


def test_extension_dtype_roundtrip_bit_exact(tmp_path):
    """bfloat16 (any ml_dtypes extension dtype) survives the .npy trip:
    numpy reloads it as a raw void record, and restore must bit-view it
    back — .astype raises 'no cast function' and a value-cast would not
    be bit-exact anyway.  This is what engine crash-restart exercises on
    every bf16 cache."""
    x = (jnp.arange(64, dtype=jnp.float32) / 7.0).astype(jnp.bfloat16)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"x": x})
    out = mgr.restore({"x": jnp.zeros(64, dtype=jnp.bfloat16)}, step=2)
    assert out["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["x"]).view(np.uint16),
        np.asarray(x).view(np.uint16), err_msg="bf16 bits changed")
