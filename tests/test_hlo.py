"""HLO collective parser unit tests (synthetic lines + a real lowering)."""

import jax
import jax.numpy as jnp

from repro.launch.hlo import collective_summary, parse_collectives

SYNTH = """
  %all-reduce.5 = f32[864,5120]{1,0} all-reduce(%fusion.3), channel_id=1, replica_groups=[32,16]<=[512]T(1,0), use_global_device_ids=true, to_apply=%add
  %ag = bf16[16,4096]{1,0} all-gather(%p0), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%x), channel_id=3, replica_groups=[4,2]<=[8], to_apply=%add
  %a2a.1 = bf16[8,64]{1,0} all-to-all(%y), channel_id=4, replica_groups=[1,8]<=[8]
  %cp = f32[32]{0} collective-permute(%z), channel_id=5, source_target_pairs={{0,1},{1,0}}
  %ard = f32[4]{0} all-reduce-done(%ar-start)
"""


def test_parse_kinds_and_groups():
    ops = parse_collectives(SYNTH)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute"]
    ar, ag, rs, a2a, cp = ops
    assert ar.group_size == 16
    assert ar.out_bytes == 864 * 5120 * 4
    assert ar.ici_bytes == int(2 * 15 / 16 * ar.out_bytes)
    assert ag.group_size == 4
    assert ag.operand_bytes == ag.out_bytes // 4
    assert rs.group_size == 2
    assert rs.operand_bytes == rs.out_bytes * 2
    assert a2a.group_size == 8
    assert cp.ici_bytes == cp.out_bytes


def test_done_ops_not_double_counted():
    ops = parse_collectives(SYNTH)
    assert not any("done" in o.line for o in ops)


def test_summary_totals():
    s = collective_summary(parse_collectives(SYNTH))
    assert s["n_ops"] == 5
    assert s["ici_bytes"] > 0
    assert set(s["by_kind"]) == {"all-reduce", "all-gather", "reduce-scatter",
                                 "all-to-all", "collective-permute"}


def test_real_lowering_has_collectives():
    """An actually-compiled sharded matmul produces parseable collectives."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("model",))
f = jax.jit(lambda x, w: jax.nn.relu(x @ w).sum(),
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))))
with mesh:
    txt = f.lower(jax.ShapeDtypeStruct((8, 16), jnp.float32),
                  jax.ShapeDtypeStruct((16, 8), jnp.float32)).compile().as_text()
import sys; sys.path.insert(0, "src")
from repro.launch.hlo import parse_collectives
ops = parse_collectives(txt)
assert any(o.kind == "all-reduce" for o in ops), [o.kind for o in ops]
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=".")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
