"""MoE routing invariants (hypothesis-driven)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import lm as lm_lib
from repro.models.moe import moe_mlp, moe_specs
from repro.models.params import tree_init
from repro.testing import reduced_config


def _run(x, cfg, nosharder, key=0):
    model_specs = moe_specs(cfg)
    params = tree_init(model_specs, jax.random.PRNGKey(key))
    return moe_mlp(params, x, cfg, nosharder)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), topk=st.sampled_from([1, 2, 4]))
def test_moe_output_finite_and_aux_positive(seed, topk):
    from repro.dist.sharding import Sharder
    nosharder = Sharder(None, {})
    cfg = reduced_config("granite-moe-1b-a400m",
                         moe=MoEConfig(8, topk, 2.0, group_size=8))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y, aux = _run(x, cfg, nosharder, key=seed)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.0  # balance loss >= 1 * coef, z-loss >= 0


def test_moe_capacity_drops_reduce_output_norm(nosharder):
    """With capacity ~0, (almost) all tokens drop -> output ~ 0; with huge
    capacity nothing drops."""
    tiny = reduced_config("granite-moe-1b-a400m",
                          moe=MoEConfig(8, 2, 0.01, group_size=8))
    big = reduced_config("granite-moe-1b-a400m",
                         moe=MoEConfig(8, 2, 100.0, group_size=8))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, tiny.d_model),
                          jnp.bfloat16)
    y_tiny, _ = _run(x, tiny, nosharder)
    y_big, _ = _run(x, big, nosharder)
    assert float(jnp.linalg.norm(y_tiny.astype(jnp.float32))) < \
        float(jnp.linalg.norm(y_big.astype(jnp.float32)))


def test_moe_balanced_router_uses_all_experts(nosharder):
    """A near-uniform router must dispatch to every expert (no collapse)."""
    cfg = reduced_config("granite-moe-1b-a400m",
                         moe=MoEConfig(8, 2, 4.0, group_size=32))
    specs = moe_specs(cfg)
    params = tree_init(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                          jnp.bfloat16)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    top1 = np.asarray(jnp.argmax(logits, -1)).ravel()
    assert len(np.unique(top1)) >= cfg.moe.n_experts // 2
