"""PR 9: tile_plans close the kernel loop — validation, planner emission,
plan IO, CLI rescoring, and the end-to-end model/engine threading that
turns a plan entry into Pallas BlockSpec geometry."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import hw
from repro.plan import ServingPlan
from repro.plan import io as plan_io
from repro.plan import planner
from repro.plan.plan import TILE_PLAN_KINDS, tiles_summary


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


GOOD_TILE_PLANS = [
    {},
    {"rwkv": {"bh": 128, "resident": True}},
    {"rwkv": {"bh": 64, "persistent": True, "resident": True,
              "impl": "auto"}},
    {"attn": {"bq": 128, "bk": 512},
     "matmul_int8": {"bm": 256, "bn": 256, "bk": 512}},
    {"fused_rnn": {"bh": 256, "n_tiles": 8, "vmem_bytes": 1024,
                   "resident": True, "step_latency_s": 1e-6, "util": 0.9,
                   "bound": "vmem"}},
]

BAD_TILE_PLANS = [
    {"bogus_kernel": {"bh": 8}},          # unknown kernel kind
    {"rwkv": [128]},                      # entry must be a mapping
    {"rwkv": {"bh": 0}},                  # tiles must be positive
    {"rwkv": {"bh": -8}},
    {"rwkv": {"bh": True}},               # bool is not a tile size
    {"rwkv": {"impl": "cuda"}},           # unknown dispatch impl
    {"rwkv": {"frobnicate": 3}},          # unknown field
    {"rwkv": {"persistent": "yes", "resident": True}},
    {"rwkv": {"persistent": True}},       # persistent needs resident proof
    {"rwkv": {"persistent": True, "resident": True,
              "vmem_bytes": 2 ** 40}},    # ... that actually fits VMEM
]


@pytest.mark.parametrize("tp", GOOD_TILE_PLANS)
def test_validate_accepts(tp):
    ServingPlan(arch="rwkv6-1.6b", tile_plans=tp).validate()


@pytest.mark.parametrize("tp", BAD_TILE_PLANS)
def test_validate_rejects(tp):
    with pytest.raises(ValueError):
        ServingPlan(arch="rwkv6-1.6b", tile_plans=tp).validate()


def test_tiles_summary():
    s = tiles_summary({
        "attn": {"bq": 256, "bk": 1024},
        "rwkv": {"bh": 512, "persistent": True, "resident": True},
    })
    assert "attn[bq256,bk1024]" in s
    assert "rwkv[bh512,persist]" in s


# ---------------------------------------------------------------------------
# planner emission
# ---------------------------------------------------------------------------


def test_tile_plans_for_rwkv():
    tp = planner.tile_plans_for("rwkv6-1.6b", 8, hw.DEFAULT, max_len=1024)
    assert set(tp) == {"rwkv"}
    entry = tp["rwkv"]
    assert entry["bh"] == 512 and entry["resident"] is True
    # n_tiles == 4: streamed, so the planner must NOT claim persistence
    assert entry["n_tiles"] == 4 and "persistent" not in entry
    ServingPlan(arch="rwkv6-1.6b", tile_plans=tp).validate()


def test_tile_plans_for_attn_families():
    tp = planner.tile_plans_for("gemma2-9b", 8, hw.DEFAULT, max_len=1024)
    assert set(tp) == {"attn", "local"}
    for entry in tp.values():
        assert entry["bq"] > 0 and entry["bk"] > 0
    ServingPlan(arch="gemma2-9b", tile_plans=tp).validate()


def test_tile_plans_for_hybrid_marks_persistent():
    """hymba's SSD half fits VMEM whole (n_tiles == 1, resident) — the
    planner must emit the persistent marker, with the DSE evidence that
    ``ServingPlan.validate`` demands alongside it."""
    tp = planner.tile_plans_for("hymba-1.5b", 8, hw.DEFAULT, max_len=1024)
    assert set(tp) == {"attn", "swa_ssm"}
    ssm = tp["swa_ssm"]
    assert ssm["persistent"] is True
    assert ssm["n_tiles"] == 1 and ssm["resident"] is True
    assert ssm["vmem_bytes"] <= hw.vmem_budget()
    ServingPlan(arch="hymba-1.5b", tile_plans=tp).validate()


def test_tile_plans_are_batch_aware():
    """Scored at the plan's max_batch: more decode lanes shrink the VMEM
    share left for weights, so the chosen design must change."""
    tp1 = planner.tile_plans_for("rwkv6-1.6b", 1, hw.DEFAULT)
    tp256 = planner.tile_plans_for("rwkv6-1.6b", 256, hw.DEFAULT)
    assert tp1["rwkv"] != tp256["rwkv"]


# ---------------------------------------------------------------------------
# plan IO
# ---------------------------------------------------------------------------


def test_plan_io_round_trips_tile_plans(tmp_path):
    tp = planner.tile_plans_for("rwkv6-1.6b", 8, hw.DEFAULT, max_len=1024)
    plan = ServingPlan(arch="rwkv6-1.6b", max_batch=8, tile_plans=tp)
    path = str(tmp_path / "plan.json")
    plan_io.save_plan(plan, path)
    loaded = plan_io.load_plan(path)
    assert dict(loaded.tile_plans) == dict(plan.tile_plans)
    loaded.validate()


def test_check_schema_covers_tile_plans():
    plan_io.check_schema()   # raises if tile_plans drift from the schema


# ---------------------------------------------------------------------------
# CLI: --hw-spec rescoring and staleness recompute
# ---------------------------------------------------------------------------


def _resolve(argv):
    from repro.launch.serve import build_parser, resolve_plan
    parser = build_parser()
    return resolve_plan(parser.parse_args(argv), parser)


def test_cli_hw_spec_scores_tile_plans():
    plan = _resolve(["--arch", "rwkv6-1.6b", "--hw-spec", "tpu-v5e"])
    assert plan.tile_plans
    expect = planner.tile_plans_for("rwkv6-1.6b", plan.max_batch,
                                    hw.TPU_V5E, max_len=plan.max_len)
    assert dict(plan.tile_plans) == expect
    assert "tile_plans" in plan.provenance["cli_overrides"]


def test_cli_hw_spec_other_silicon_differs():
    v5e = _resolve(["--arch", "rwkv6-1.6b", "--hw-spec", "tpu-v5e"])
    pls = _resolve(["--arch", "rwkv6-1.6b", "--hw-spec",
                    "plasticine-rnn-variant"])
    assert dict(v5e.tile_plans) != dict(pls.tile_plans)


def test_cli_unknown_hw_spec_errors():
    with pytest.raises(SystemExit):
        _resolve(["--arch", "rwkv6-1.6b", "--hw-spec", "tpu-v9"])


def test_cli_override_recomputes_stale_tile_plans(tmp_path):
    """A --plan file carries tile plans scored at its own max_batch; a
    --max-batch override makes that kernel half stale, so resolve_plan
    must rescore rather than serve the old geometry."""
    tp = planner.tile_plans_for("rwkv6-1.6b", 4, hw.DEFAULT, max_len=128)
    base = ServingPlan(arch="rwkv6-1.6b", max_batch=4, max_len=128,
                       tile_plans=tp)
    path = str(tmp_path / "plan.json")
    plan_io.save_plan(base, path)
    plan = _resolve(["--plan", path, "--max-batch", "256"])
    expect = planner.tile_plans_for("rwkv6-1.6b", 256, hw.DEFAULT,
                                    max_len=128)
    assert dict(plan.tile_plans) == expect
    assert dict(plan.tile_plans) != tp


# ---------------------------------------------------------------------------
# end-to-end: plan entry -> model -> kernel grid
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rwkv_setup():
    from repro.dist.sharding import Sharder
    from repro.models.lm import build_model
    from repro.testing import reduced_config

    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, Sharder(None, {})


def test_engine_threads_tile_plans(rwkv_setup):
    from repro.serving import ServingEngine

    cfg, model, params, sharder = rwkv_setup
    plan = ServingPlan(arch="rwkv6-1.6b", max_batch=2, max_len=32,
                       tile_plans={"rwkv": {"impl": "pallas", "bh": 64}})
    eng = ServingEngine.from_plan(plan, params, model=model,
                                  sharder=sharder)
    assert eng.model.tile_plans == dict(plan.tile_plans)
    assert eng.model is not model        # rebound, original untouched
    assert model.tile_plans == {}


def test_engine_output_invariant_under_tile_plans(rwkv_setup):
    """Greedy decode tokens must be identical whether the rwkv layers run
    on the jnp path, auto dispatch, or the forced Pallas kernel under an
    explicit head tile — the plan changes the schedule, never the math."""
    from repro.serving import ServingEngine

    cfg, model, params, sharder = rwkv_setup
    outs = []
    for tp in ({}, {"rwkv": {"impl": "auto"}},
               {"rwkv": {"impl": "pallas", "bh": cfg.rwkv.head_dim}}):
        plan = ServingPlan(arch="rwkv6-1.6b", max_batch=2, max_len=32,
                           tile_plans=tp)
        eng = ServingEngine.from_plan(plan, params, model=model,
                                      sharder=sharder)
        r = eng.submit([3, 5, 7], max_new_tokens=6)
        eng.run()
        outs.append(r.output)
    assert outs[0] == outs[1] == outs[2]


def test_tile_plan_reaches_lowered_program(rwkv_setup):
    """HLO-level proof the plan reaches the hardware: changing only the
    head tile changes the lowered decode program (different Pallas grid)
    while the logits stay bit-identical in interpret mode."""
    cfg, model, params, sharder = rwkv_setup
    prompts = jax.numpy.asarray([[3, 5, 7, 9]], jax.numpy.int32)
    cache, _ = model.prefill(params, {"tokens": prompts}, sharder,
                             max_len=16)
    tokens = jax.numpy.asarray([11], jax.numpy.int32)

    def lower_and_run(tp):
        m = model.with_tile_plans(tp)
        fn = jax.jit(lambda p, c, t: m.decode_step(p, c, t, sharder))
        text = fn.lower(params, cache, tokens).as_text()
        _, logits = fn(params, cache, tokens)
        return text, np.asarray(logits)

    hd = cfg.rwkv.head_dim
    text_jnp, logits_jnp = lower_and_run({})
    text_a, logits_a = lower_and_run({"rwkv": {"impl": "pallas"}})
    text_b, logits_b = lower_and_run({"rwkv": {"impl": "pallas",
                                               "bh": hd}})
    assert text_a != text_jnp            # kernel path actually engaged
    assert text_a != text_b              # bh reached the BlockSpec grid
    assert (logits_a == logits_b).all()  # ... without touching the math
    np.testing.assert_allclose(logits_a, logits_jnp, atol=2e-2, rtol=2e-2)
