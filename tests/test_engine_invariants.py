"""ServingEngine invariants: conservation, output bounds, slot isolation,
and bit-reproducibility (the engine half of the serving-load contract —
the workload half lives in tests/test_workload.py)."""

import jax
import pytest

from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.serving import ServingEngine, VirtualClock, drive, make_workload
from repro.serving.sampler import SamplerConfig
from repro.testing import reduced_config


# Every invariant in this module runs against BOTH cache layouts by
# construction: the module fixture is parameterized over cache_layout, and
# _engine() threads it into every engine it builds (PR 7 — the paged
# backing store promises dense-identical behaviour, so the whole file is
# its regression net).
@pytest.fixture(scope="module", params=("dense", "paged:8"),
                ids=("dense", "paged8"))
def setup(request):
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, Sharder(None, {}), request.param


def _engine(setup, **kw):
    cfg, model, params = setup[:3]
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("cache_layout", setup[4])
    return ServingEngine(model, params, setup[3], **kw)


def test_drained_run_conserves_requests(setup):
    """submitted == completed == finished after a full drain; no request is
    lost or duplicated, and output lengths never exceed max_new_tokens
    (including the max_new_tokens=1 admit-tick completion edge)."""
    eng = _engine(setup)
    reqs = [eng.submit([1, 2, 3 + i], max_new_tokens=1 + i % 5)
            for i in range(7)]
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.completed == len(reqs) == len(eng.finished)
    assert sorted(r.uid for r in eng.finished) == [r.uid for r in reqs]
    assert not eng.has_work()
    assert eng.stats()["active"] == 0 and eng.stats()["queued"] == 0
    for r in reqs:
        assert 1 <= len(r.output) <= r.max_new_tokens


def test_output_bound_under_open_loop_arrivals(setup):
    """The length invariant holds under asynchronous (Poisson) arrivals
    too, where admits and completions interleave arbitrarily."""
    cfg = setup[0]
    eng = _engine(setup)
    items = make_workload("poisson", rate=0.8, duration=16.0, seed=2,
                          vocab_size=cfg.vocab_size, prompt_len=(2, 6),
                          max_new_tokens=(1, 6))
    reqs = drive(eng, items, VirtualClock())
    assert len(reqs) == eng.completed
    for r in reqs:
        assert r.done and 1 <= len(r.output) <= r.max_new_tokens


def test_prefill_only_ticks_advance_time(setup):
    """An all-max_new_tokens=1 workload finishes every request at its
    prefill token; time must still advance (no frozen stamps, no NaN
    throughput) and a freed slot admits the next request in the same
    tick rather than idling it."""
    from repro.serving import aggregate

    eng = _engine(setup, max_batch=1)
    reqs = [eng.submit([1, 2, 3 + i], max_new_tokens=1) for i in range(3)]
    eng.run()
    assert all(r.done and len(r.output) == 1 for r in reqs)
    assert eng.ticks >= 1
    assert [r.t_done for r in reqs] == [0, 0, 0]  # same-tick slot reuse
    agg = aggregate(reqs, ticks=eng.ticks, util_history=eng.util_history)
    assert agg["tokens_per_sec"] > 0
    # util reports the TRUE ratio: 3 instant admits through 1 slot in one
    # tick -> 3.0, not clamped to 1.0; the clamp used to hide over-unity
    # instant-admit ticks.  stats() counts them explicitly.
    assert agg["mean_util"] == 3.0
    assert eng.stats()["instant_admits"] == 3


def test_reset_telemetry_requires_drained_engine(setup):
    eng = _engine(setup)
    r = eng.submit([1, 2, 3], max_new_tokens=2)
    with pytest.raises(RuntimeError):
        eng.reset_telemetry()
    eng.run()
    eng.reset_telemetry()
    assert eng.ticks == 0 and eng.completed == 0 and not eng.finished
    assert r.done  # the drained request itself is untouched


def test_eos_stops_slot_without_disturbing_neighbors(setup):
    """Forcing an early EOS on one slot must not change what the other
    slot generates (greedy decoding)."""
    prompt_a, prompt_b = [5, 9, 3, 7], [2, 4, 6, 8, 10]

    solo_a = _engine(setup, max_batch=1)
    ra = solo_a.submit(list(prompt_a), max_new_tokens=8)
    solo_a.run()
    solo_b = _engine(setup, max_batch=1)
    rb = solo_b.submit(list(prompt_b), max_new_tokens=8)
    solo_b.run()

    # pick B's 3rd token as EOS: B must stop at its first emission of it
    eos = rb.output[2]
    stop_at = rb.output.index(eos) + 1
    multi = _engine(setup)
    ma = multi.submit(list(prompt_a), max_new_tokens=8)
    mb = multi.submit(list(prompt_b), max_new_tokens=8, eos_id=eos)
    multi.run()
    assert mb.output == rb.output[:stop_at]          # stopped by EOS
    assert ma.output == ra.output                    # neighbor undisturbed
    assert ma.t_done is not None and mb.t_done is not None
    assert mb.t_done < ma.t_done                     # B's slot freed early


def test_fixed_seed_bit_reproducible_across_constructions(setup):
    """Two engines built with the same seed replay a stochastic-sampling
    workload identically: same tokens, same tick stamps, same stats."""
    cfg = setup[0]

    def one():
        eng = _engine(setup, seed=123,
                      sampler=SamplerConfig(temperature=0.8, top_k=5))
        items = make_workload("mmpp", rate=0.4, duration=12.0, seed=9,
                              vocab_size=cfg.vocab_size, prompt_len=(2, 5),
                              max_new_tokens=(2, 5))
        reqs = drive(eng, items, VirtualClock())
        return ([(r.output, r.t_submit, r.t_admit, r.t_first, r.t_done)
                 for r in reqs], eng.stats(), eng.util_history)

    assert one() == one()
