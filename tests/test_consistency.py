"""Incremental decoding == full forward, per architecture.

The strongest end-to-end invariant: prefilling P tokens and decoding the
remaining S-P one at a time must produce the same final-position logits as
prefilling all S at once.  Exercises chunked-vs-step recurrences (rwkv,
ssd), KV cache layout, ring buffers, cross-attention caching, M-RoPE
positions, and GQA decode attention in one assertion.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import MoEConfig
from repro.models import lm as lm_lib
from repro.testing import reduced_config

# MoE archs run with a no-drop capacity factor: GShard capacity drops are
# legitimately grouping-dependent, so exact prefill/decode equivalence only
# holds when nothing overflows.  hymba's parallel attention+SSM paths sum
# two independently-rounded bf16 streams per layer, so its drift is ~2x.
TOL = {"default": 0.02, "hymba-1.5b": 0.05}


def _build(arch):
    cfg = reduced_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=16.0,
                               group_size=16))
    return cfg, lm_lib.build_model(cfg)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_full_forward(arch, nosharder):
    cfg, model = _build(arch)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S, P = 2, 12, 9
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S // cfg.encoder_downsample, cfg.d_model)),
            jnp.bfloat16)
    if cfg.m_rope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, 3, S))

    def cut(b, n):
        return {k: (v[:, :n] if k == "tokens" else
                    (v[..., :n] if k == "positions" else v))
                for k, v in b.items()}

    cache, _ = model.prefill(params, cut(batch, P), nosharder, max_len=S)
    for t in range(P, S):
        cache, logits_d = model.decode_step(params, cache, tokens[:, t],
                                            nosharder)
    _, logits_full = model.prefill(params, batch, nosharder, max_len=S)

    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits_d - logits_full))) / scale
    assert rel < TOL.get(arch, TOL["default"]), f"{arch}: rel err {rel:.4f}"
