"""Fault injection + recovery (PR 8).

The contract under test: every fault class in
:mod:`repro.serving.faults` is (a) survivable — each submitted request
either completes or is accountably shed, never silently lost or
silently wrong — and (b) deterministic — the same seeded workload under
the same :class:`FaultPlan` replays byte-identically, and a
killed-and-restored engine finishes with a schedule bit-identical to an
uninterrupted run.  Recovery must also be *clean*: when every faulted
request survives its retries, the final outputs match a fault-free run
of the same workload token-for-token (rollback restores the exact
pre-fault state; greedy decode then reproduces the same tokens).
"""

import json

import jax
import pytest

from repro.checkpoint import CheckpointManager
from repro.dist.sharding import Sharder
from repro.models.lm import build_model
from repro.plan.plan import ServingPlan
from repro.serving import (FaultInjector, FaultPlan, FaultReport, FaultSpec,
                           ServingEngine, VirtualClock, drive,
                           drive_resilient, make_workload)
from repro.serving.faults import make_storm
from repro.testing import reduced_config


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("rwkv6-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, Sharder(None, {})


def _plan(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    return ServingPlan(arch="rwkv6-1.6b", reduced=True, **kw).resolve()


def _engine(setup, **kw):
    cfg, model, params, sharder = setup
    return ServingEngine.from_plan(_plan(**kw), params, model=model,
                                   sharder=sharder)


def _items(setup, *, rate=0.8, duration=20.0, seed=7):
    cfg = setup[0]
    return make_workload("poisson", rate=rate, duration=duration, seed=seed,
                         vocab_size=cfg.vocab_size, prompt_len=(3, 8),
                         max_new_tokens=(4, 10))


def _schedule(reqs):
    return {r.uid: (tuple(r.output), r.t_admit, r.t_first, r.t_done)
            for r in reqs}


def _outputs(reqs):
    return {r.uid: tuple(r.output) for r in reqs}


def _baseline(setup, items):
    """The fault-free run every clean recovery must reproduce exactly."""
    return _schedule(drive(_engine(setup), items, VirtualClock()))


# ---------------------------------------------------------------------------
# FaultSpec / FaultPlan: schema discipline
# ---------------------------------------------------------------------------


def test_spec_roundtrip():
    s = FaultSpec("poison_slot", tick=7, slot=2, mode="garbage", seed=3)
    assert FaultSpec.from_json(json.loads(json.dumps(s.to_json()))) == s


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("melt_tpu", tick=1).validate()
    with pytest.raises(ValueError, match="tick must be >= 0"):
        FaultSpec("poison_slot", tick=-1).validate()
    with pytest.raises(ValueError, match="unknown poison mode"):
        FaultSpec("poison_slot", tick=1, mode="gremlins").validate()
    with pytest.raises(ValueError, match="unknown FaultSpec fields"):
        FaultSpec.from_json({"kind": "poison_slot", "tick": 1, "wat": 2})
    with pytest.raises(ValueError, match="needs at least"):
        FaultSpec.from_json({"kind": "poison_slot"})


def test_plan_roundtrip_and_save_load(tmp_path):
    p = FaultPlan((FaultSpec("kill_engine", tick=9),
                   FaultSpec("stall_slot", tick=3, slot=1)))
    assert FaultPlan.from_dict(json.loads(json.dumps(p.to_dict()))) == p
    assert p.needs_watchdog() and p.needs_checkpoints()
    assert p.kinds == ("kill_engine", "stall_slot")
    path = str(tmp_path / "fp.json")
    p.save(path)
    assert FaultPlan.load(path) == p
    with pytest.raises(ValueError, match="unsupported fault-plan schema"):
        FaultPlan.from_dict({"schema": "fault_plan/v9", "faults": []})
    with pytest.raises(ValueError, match="unknown FaultPlan fields"):
        FaultPlan.from_dict({"faults": [], "extra": 1})


def test_injector_one_shot():
    inj = FaultInjector(FaultPlan((FaultSpec("poison_slot", tick=2),)))
    assert inj.due(1) == []
    (idx, spec), = inj.due(5)
    inj.fire(idx, 5)
    assert inj.due(5) == [] and inj.pending() == 0
    assert inj.log[0]["fired_at"] == 5
    with pytest.raises(ValueError, match="already fired"):
        inj.fire(idx, 6)


def test_make_storm_deterministic():
    a, b = make_storm(duration=30, seed=5), make_storm(duration=30, seed=5)
    assert a == b
    assert sum(s.kind == "kill_engine" for s in a.faults) <= 1
    with pytest.raises(ValueError, match="unknown fault kind"):
        make_storm(duration=10, kinds=("melt_tpu",))


# ---------------------------------------------------------------------------
# Recovery: each fault class, clean runs reproduce the fault-free outputs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ("nan", "garbage"))
def test_poison_quarantine_retry_complete(setup, mode):
    items = _items(setup)
    base = _outputs(drive(_engine(setup), items, VirtualClock()))
    eng = _engine(setup)
    inj = FaultInjector(FaultPlan(
        (FaultSpec("poison_slot", tick=4, slot=0, mode=mode, seed=9),)))
    rep = drive_resilient(eng, items, VirtualClock(), injector=inj)
    fs = eng.fault_stats()
    assert fs == {"injected": 1, "quarantined": 1, "retries": 1,
                  "shed": 0, "watchdog_evictions": 0}
    assert not rep.lost_uids() and not rep.shed_uids
    # recovery costs ticks (timings shift) but never tokens: outputs are
    # token-for-token the fault-free run's
    assert _outputs(rep.completed) == base
    ev, = rep.fault_events
    assert ev["kind"] == "poison" and ev["recovered_at"] is not None


def test_retry_budget_exhaustion_sheds(setup):
    items = _items(setup)
    base = _outputs(drive(_engine(setup), items, VirtualClock()))
    eng = _engine(setup, retry_budget=0)
    inj = FaultInjector(FaultPlan(
        (FaultSpec("poison_slot", tick=4, slot=0),)))
    rep = drive_resilient(eng, items, VirtualClock(), injector=inj)
    fs = eng.fault_stats()
    assert fs["shed"] == 1 and fs["retries"] == 0
    assert len(rep.shed_uids) == 1
    assert not rep.lost_uids()              # shed is accounted, not lost
    shed = next(r for r in rep.requests if r.shed)
    assert not shed.done
    # whatever it emitted before the fault is genuine: a prefix of the
    # fault-free run's tokens — suspect (post-poison) tokens never land
    assert tuple(shed.output) == base[shed.uid][:len(shed.output)]


def test_stall_watchdog_recovers(setup):
    items = _items(setup)
    base = _outputs(drive(_engine(setup), items, VirtualClock()))
    eng = _engine(setup, watchdog_ticks=3)
    inj = FaultInjector(FaultPlan((FaultSpec("stall_slot", tick=5, slot=1),)))
    rep = drive_resilient(eng, items, VirtualClock(), injector=inj)
    fs = eng.fault_stats()
    assert fs["watchdog_evictions"] == 1 and fs["quarantined"] == 1
    assert not rep.lost_uids() and not rep.shed_uids
    assert _outputs(rep.completed) == base


def test_stall_without_watchdog_rejected(setup):
    eng = _engine(setup)   # watchdog_ticks=0
    inj = FaultInjector(FaultPlan((FaultSpec("stall_slot", tick=5),)))
    with pytest.raises(ValueError, match="watchdog"):
        eng.attach_injector(inj)


def test_fail_prefill_retries(setup):
    items = _items(setup)
    base = _outputs(drive(_engine(setup), items, VirtualClock()))
    eng = _engine(setup)
    inj = FaultInjector(FaultPlan((FaultSpec("fail_prefill", tick=2),)))
    rep = drive_resilient(eng, items, VirtualClock(), injector=inj)
    fs = eng.fault_stats()
    assert fs["injected"] == 1 and fs["retries"] >= 1
    assert not rep.lost_uids() and not rep.shed_uids
    assert _outputs(rep.completed) == base


def test_drop_readback_rolls_back(setup):
    items = _items(setup)
    base = _outputs(drive(_engine(setup), items, VirtualClock()))
    eng = _engine(setup)
    inj = FaultInjector(FaultPlan((FaultSpec("drop_readback", tick=6),)))
    rep = drive_resilient(eng, items, VirtualClock(), injector=inj)
    fs = eng.fault_stats()
    assert fs["injected"] == 1 and fs["quarantined"] >= 1
    assert not rep.lost_uids() and not rep.shed_uids
    assert _outputs(rep.completed) == base


def test_fault_free_stats_surface_unchanged(setup):
    """Byte-stability guard: a no-fault engine exposes no fault keys in
    stats() and emits no fault events — the committed BENCH blocks and
    traces cannot shift."""
    eng = _engine(setup)
    drive(eng, _items(setup), VirtualClock())
    assert not any(k.startswith("fault") for k in eng.stats())
    assert eng.fault_events == []
    assert eng.fault_stats() == {"injected": 0, "quarantined": 0,
                                 "retries": 0, "shed": 0,
                                 "watchdog_evictions": 0}


# ---------------------------------------------------------------------------
# Crash-restart: the checkpoint/restore proof
# ---------------------------------------------------------------------------


def test_crash_restart_bit_identical(setup, tmp_path):
    """THE tentpole proof: kill the engine mid-run; the restored run loses
    zero requests and finishes with a schedule bit-identical to a run
    that was never killed."""
    items = _items(setup)
    base = _baseline(setup, items)
    mgr = CheckpointManager(str(tmp_path))
    inj = FaultInjector(FaultPlan((FaultSpec("kill_engine", tick=9),)))
    rep = drive_resilient(_engine(setup), items, VirtualClock(),
                          injector=inj, manager=mgr, checkpoint_every=4)
    assert rep.n_restarts == 1
    assert not rep.lost_uids() and not rep.shed_uids
    assert sorted(_schedule(rep.requests)) == sorted(base)   # no dup uids
    assert _schedule(rep.requests) == base
    assert rep.engine.fault_stats()["injected"] == 1
    kill_evs = [e for e in rep.fault_events if e["kind"] == "kill_engine"]
    assert len(kill_evs) == 1   # the consumed kill did not re-fire


def test_kill_without_manager_rejected(setup):
    inj = FaultInjector(FaultPlan((FaultSpec("kill_engine", tick=3),)))
    with pytest.raises(ValueError, match="CheckpointManager"):
        drive_resilient(_engine(setup), _items(setup), VirtualClock(),
                        injector=inj)


def test_resilient_driver_requires_virtual_clock(setup):
    from repro.serving import WallClock
    with pytest.raises(ValueError, match="VirtualClock"):
        drive_resilient(_engine(setup), _items(setup), WallClock())


def test_resilient_no_faults_matches_drive(setup):
    """drive_resilient with no injector and no manager is drive()."""
    items = _items(setup)
    base = _baseline(setup, items)
    rep = drive_resilient(_engine(setup), items, VirtualClock())
    assert isinstance(rep, FaultReport) and rep.n_restarts == 0
    assert _schedule(rep.requests) == base


# ---------------------------------------------------------------------------
# Determinism: same seed + same FaultPlan -> byte-identical chaos runs
# ---------------------------------------------------------------------------


def test_chaos_runs_byte_identical(setup, tmp_path):
    items = _items(setup, duration=24.0)
    storm = make_storm(duration=20, seed=2, max_batch=2,
                       kinds=("poison_slot", "fail_prefill", "kill_engine",
                              "drop_readback"))

    def run(d):
        mgr = CheckpointManager(str(tmp_path / d))
        rep = drive_resilient(_engine(setup), items, VirtualClock(),
                              injector=FaultInjector(storm), manager=mgr,
                              checkpoint_every=4)
        assert not rep.lost_uids()
        return json.dumps({
            "schedule": sorted(_schedule(rep.requests).items()),
            "events": rep.fault_events,
            "stats": rep.engine.fault_stats(),
            "restarts": rep.n_restarts,
        }, sort_keys=True)

    assert run("a") == run("b")
