"""Workload generators + metrics aggregator: pure, model-free tests."""

import math

import numpy as np
import pytest

from repro.serving.engine import Request
from repro.serving import metrics as sm
from repro.serving import workload as wl


# ---------------------------------------------------------------- arrivals


def test_poisson_seeded_determinism():
    a = wl.poisson_arrivals(0.5, 100.0, np.random.default_rng(7))
    b = wl.poisson_arrivals(0.5, 100.0, np.random.default_rng(7))
    assert a == b
    assert all(0 < t < 100.0 for t in a)
    assert a == sorted(a)


def test_poisson_rate_scaling():
    rng = np.random.default_rng(0)
    n_slow = len(wl.poisson_arrivals(0.2, 500.0, rng))
    rng = np.random.default_rng(0)
    n_fast = len(wl.poisson_arrivals(2.0, 500.0, rng))
    # E[n] = 100 vs 1000; seeded draws sit well within loose bounds
    assert 50 < n_slow < 200
    assert 700 < n_fast < 1400


def test_mmpp_valid_and_bursty():
    rng = np.random.default_rng(3)
    times = wl.mmpp_arrivals((0.2, 4.0), (20.0, 10.0), 400.0, rng)
    assert times == sorted(times)
    assert all(0 < t < 400.0 for t in times)
    # burst state at 20x the quiet rate must beat the all-quiet expectation
    assert len(times) > 0.2 * 400.0


def test_make_workload_deterministic_and_bounded():
    kw = dict(rate=1.0, duration=50.0, seed=11, vocab_size=503,
              prompt_len=(4, 12), max_new_tokens=(8, 16))
    a = wl.make_workload("poisson", **kw)
    b = wl.make_workload("poisson", **kw)
    assert a == b
    for it in a:
        assert 4 <= len(it.prompt) <= 12
        assert 8 <= it.max_new_tokens <= 16
        assert all(0 <= tok < 503 for tok in it.prompt)


def test_make_workload_rejects_unknown_kind():
    with pytest.raises(ValueError):
        wl.make_workload("uniform", rate=1.0, duration=1.0, seed=0,
                         vocab_size=10)


def test_trace_round_trip(tmp_path):
    items = wl.make_workload("mmpp", rate=0.5, duration=40.0, seed=5,
                             vocab_size=100)
    path = str(tmp_path / "trace.jsonl")
    wl.save_trace(path, items)
    assert wl.load_trace(path) == sorted(items, key=lambda it: it.t)
    # and the trace kind replays the file verbatim
    again = wl.make_workload("trace", rate=0.0, duration=0.0, seed=0,
                             vocab_size=0, trace_path=path)
    assert again == wl.load_trace(path)


def test_offered_load():
    items = [wl.WorkloadItem(1.0, (1, 2), 3), wl.WorkloadItem(2.0, (1,), 4)]
    # declared duration divides the real span, not the last-arrival time
    assert wl.offered_load(items, 5.0) == pytest.approx(10 / 5.0)
    # no duration (trace replay): last arrival stands in
    assert wl.offered_load(items) == pytest.approx(10 / 2.0)
    assert wl.offered_load([]) == 0.0


def test_trace_deadline_round_trip_and_backward_compat(tmp_path):
    """The optional deadline survives the JSONL round trip, is omitted
    when absent, and pre-deadline trace lines load unchanged."""
    items = [wl.WorkloadItem(1.0, (1, 2), 3, deadline=25.5),
             wl.WorkloadItem(2.0, (4,), 5)]
    path = str(tmp_path / "trace.jsonl")
    wl.save_trace(path, items)
    again = wl.load_trace(path)
    assert again[0].deadline == 25.5 and again[1].deadline is None
    assert "deadline" not in items[1].to_json()
    # a trace written before the deadline field existed still loads
    legacy = wl.WorkloadItem.from_json({"t": 3.0, "prompt": [7, 8]})
    assert legacy.deadline is None and legacy.max_new_tokens == 16


def test_deadline_slack_is_decode_proportional():
    items = wl.make_workload("poisson", rate=1.0, duration=20.0, seed=3,
                             vocab_size=100, deadline_slack=3.0)
    assert items
    for it in items:
        assert it.deadline == pytest.approx(it.t + 3.0 * it.max_new_tokens)
    # frac < 1 leaves a seeded subset best-effort; frac is respected
    mixed = wl.make_workload("poisson", rate=2.0, duration=60.0, seed=3,
                             vocab_size=100, deadline_slack=3.0,
                             deadline_frac=0.5)
    n_dl = sum(it.deadline is not None for it in mixed)
    assert 0 < n_dl < len(mixed)
    # and by default nothing carries a deadline (historical behaviour)
    plain = wl.make_workload("poisson", rate=1.0, duration=20.0, seed=3,
                             vocab_size=100)
    assert all(it.deadline is None for it in plain)


def test_prompt_length_distributions():
    kw = dict(rate=1.0, duration=60.0, seed=5, vocab_size=100,
              prompt_len=(4, 12))
    fixed = wl.make_workload("poisson", prompt_dist="fixed", **kw)
    assert {len(it.prompt) for it in fixed} == {8}        # midpoint
    logn = wl.make_workload("poisson", prompt_dist="lognormal",
                            prompt_len_long=40, **kw)
    lens = [len(it.prompt) for it in logn]
    assert min(lens) >= 4 and max(lens) <= 40
    assert len(set(lens)) > 3                             # actually spread
    bi = wl.make_workload("poisson", prompt_dist="bimodal",
                          prompt_len_long=48, **kw)
    lens = [len(it.prompt) for it in bi]
    assert all(4 <= n <= 12 or 36 <= n <= 48 for n in lens)
    with pytest.raises(ValueError, match="prompt_dist"):
        wl.make_workload("poisson", prompt_dist="zipf", **kw)
    # the default distribution is draw-for-draw the historical one: same
    # seed, same items as an explicit "uniform"
    assert wl.make_workload("poisson", **kw) == \
        wl.make_workload("poisson", prompt_dist="uniform", **kw)


def test_heavy_decode_mixture():
    kw = dict(rate=1.0, duration=60.0, seed=9, vocab_size=100,
              max_new_tokens=(6, 10))
    heavy = wl.make_workload("poisson", heavy_decode=(1.0, 32, 48), **kw)
    assert {32 <= it.max_new_tokens <= 48 for it in heavy} == {True}
    mixed = wl.make_workload("poisson", heavy_decode=(0.2, 32, 48), **kw)
    ms = [it.max_new_tokens for it in mixed]
    assert any(m >= 32 for m in ms) and any(m <= 10 for m in ms)


def test_virtual_clock_skip_never_rewinds():
    c = wl.VirtualClock()
    c.tick(); c.tick()
    c.skip_to(1.0)        # behind now: no-op
    assert c.now == 2.0
    c.skip_to(10.0)
    assert c.now == 10.0


# ----------------------------------------------------------------- metrics


def test_percentile_nearest_rank():
    xs = list(range(1, 101))           # 1..100
    assert sm.percentile(xs, 50) == 50
    assert sm.percentile(xs, 95) == 95
    assert sm.percentile(xs, 99) == 99
    assert sm.percentile([7.0], 99) == 7.0
    assert math.isnan(sm.percentile([], 50))


def _req(t_submit, t_admit, t_done, n_out):
    r = Request(0, [1], max_new_tokens=n_out)
    r.output = list(range(n_out))
    r.done = True
    r.t_submit, r.t_admit, r.t_first, r.t_done = (t_submit, t_admit,
                                                  t_admit, t_done)
    return r


def test_request_metrics_definitions():
    m = sm.request_metrics(_req(t_submit=2, t_admit=5, t_done=12, n_out=8))
    assert m["queue_wait"] == 3            # 5 - 2
    assert m["ttft"] == 4                  # 5 - 2 + 1 (prefill tick counts)
    assert m["tpot"] == pytest.approx(7 / 7)   # (12-5) / (8-1)
    # one-token request: no decode phase, no TPOT sample
    m1 = sm.request_metrics(_req(0, 0, 0, n_out=1))
    assert "tpot" not in m1
    # unfinished request contributes nothing
    r = Request(0, [1])
    assert sm.request_metrics(r) is None


def test_aggregate_scaling_and_counts():
    reqs = [_req(0, 0, 6, 4), _req(1, 3, 9, 4), Request(9, [1])]
    agg = sm.aggregate(reqs, ticks=10, util_history=[0.5, 1.0],
                       tick_seconds=2.0)
    assert agg["completed"] == 2 and agg["submitted"] == 3
    assert agg["tokens"] == 8
    assert agg["queue_wait"]["p99"] == 2 * 2.0     # ticks * tick_seconds
    assert agg["tokens_per_sec"] == pytest.approx(8 / 20.0)
    assert agg["mean_util"] == pytest.approx(0.75)
    # deadline-less, preemption-free runs aggregate to the historical
    # dict exactly: no slo / preemption keys (BENCH history contract)
    assert "slo" not in agg and "preemption" not in agg


def test_aggregate_slo_attainment():
    met = _req(0, 0, 6, 4)          # t_done 6, finish 7
    met.deadline = 7.0
    missed = _req(1, 3, 9, 4)       # t_done 9, finish 10
    missed.deadline = 9.5
    free = _req(2, 0, 4, 2)         # no deadline: not an SLO sample
    unfinished = Request(9, [1])
    unfinished.deadline = 100.0     # deadline'd but never completed: a miss
    agg = sm.aggregate([met, missed, free, unfinished], ticks=10)
    assert agg["slo"] == {"n": 3, "met": 1, "violations": 2,
                          "attainment": pytest.approx(1 / 3)}
    # the summary formatter surfaces it
    assert "attainment" in sm.format_summary(agg)


def test_aggregate_preemption_counters():
    r = _req(0, 0, 6, 4)
    r.n_preempts = 2
    r.t_resumes = [3, 5]
    agg = sm.aggregate([r, _req(1, 0, 4, 2)], ticks=10)
    assert agg["preemption"] == {"preemptions": 2, "resumes": 2,
                                 "preempted_requests": 1}
    assert "evictions" in sm.format_summary(agg)


# ---------------------------------------------------------------------------
# Malformed-trace hardening (PR 8): a bad JSONL line must name the file,
# line number, and offending field — not raise a bare KeyError/JSONError.
# ---------------------------------------------------------------------------


def _write(tmp_path, *lines):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def test_load_trace_truncated_json_names_line(tmp_path):
    path = _write(tmp_path, '{"t": 1.0, "prompt": [1, 2]}',
                  '{"t": 2.0, "pro')   # torn mid-write
    with pytest.raises(ValueError, match=r"bad\.jsonl:2: not valid JSON"):
        wl.load_trace(path)


def test_load_trace_missing_field_names_field_and_line(tmp_path):
    path = _write(tmp_path, '{"t": 1.0, "prompt": [1]}',
                  '{"prompt": [2, 3]}')
    with pytest.raises(ValueError,
                       match=r"bad\.jsonl:2: .*required field 't'"):
        wl.load_trace(path)
    path = _write(tmp_path, '{"t": 4.0}')
    with pytest.raises(ValueError, match=r"required field 'prompt'"):
        wl.load_trace(path)


def test_load_trace_bad_types_are_named(tmp_path):
    with pytest.raises(ValueError, match=r":1: .*'t' must be a number"):
        wl.load_trace(_write(tmp_path, '{"t": "noon", "prompt": [1]}'))
    with pytest.raises(ValueError, match=r"'prompt' must be a list"):
        wl.load_trace(_write(tmp_path, '{"t": 1.0, "prompt": "hi"}'))
    with pytest.raises(ValueError, match=r"integer token ids"):
        wl.load_trace(_write(tmp_path, '{"t": 1.0, "prompt": [1, "x"]}'))
    with pytest.raises(ValueError, match=r"'max_new_tokens' must be an int"):
        wl.load_trace(_write(
            tmp_path, '{"t": 1.0, "prompt": [1], "max_new_tokens": "many"}'))
    with pytest.raises(ValueError, match=r"'deadline' must be a number"):
        wl.load_trace(_write(
            tmp_path, '{"t": 1.0, "prompt": [1], "deadline": "soon"}'))


def test_load_trace_unknown_field_and_non_object(tmp_path):
    with pytest.raises(ValueError, match=r"unknown fields \['priority'\]"):
        wl.load_trace(_write(
            tmp_path, '{"t": 1.0, "prompt": [1], "priority": 9}'))
    with pytest.raises(ValueError, match=r"must be a JSON object, got list"):
        wl.load_trace(_write(tmp_path, '[1, 2, 3]'))


def test_load_trace_skips_blank_lines(tmp_path):
    path = _write(tmp_path, '{"t": 2.0, "prompt": [1]}', '',
                  '{"t": 1.0, "prompt": [2]}', '   ')
    items = wl.load_trace(path)
    assert [it.t for it in items] == [1.0, 2.0]
