"""Workload generators + metrics aggregator: pure, model-free tests."""

import math

import numpy as np
import pytest

from repro.serving.engine import Request
from repro.serving import metrics as sm
from repro.serving import workload as wl


# ---------------------------------------------------------------- arrivals


def test_poisson_seeded_determinism():
    a = wl.poisson_arrivals(0.5, 100.0, np.random.default_rng(7))
    b = wl.poisson_arrivals(0.5, 100.0, np.random.default_rng(7))
    assert a == b
    assert all(0 < t < 100.0 for t in a)
    assert a == sorted(a)


def test_poisson_rate_scaling():
    rng = np.random.default_rng(0)
    n_slow = len(wl.poisson_arrivals(0.2, 500.0, rng))
    rng = np.random.default_rng(0)
    n_fast = len(wl.poisson_arrivals(2.0, 500.0, rng))
    # E[n] = 100 vs 1000; seeded draws sit well within loose bounds
    assert 50 < n_slow < 200
    assert 700 < n_fast < 1400


def test_mmpp_valid_and_bursty():
    rng = np.random.default_rng(3)
    times = wl.mmpp_arrivals((0.2, 4.0), (20.0, 10.0), 400.0, rng)
    assert times == sorted(times)
    assert all(0 < t < 400.0 for t in times)
    # burst state at 20x the quiet rate must beat the all-quiet expectation
    assert len(times) > 0.2 * 400.0


def test_make_workload_deterministic_and_bounded():
    kw = dict(rate=1.0, duration=50.0, seed=11, vocab_size=503,
              prompt_len=(4, 12), max_new_tokens=(8, 16))
    a = wl.make_workload("poisson", **kw)
    b = wl.make_workload("poisson", **kw)
    assert a == b
    for it in a:
        assert 4 <= len(it.prompt) <= 12
        assert 8 <= it.max_new_tokens <= 16
        assert all(0 <= tok < 503 for tok in it.prompt)


def test_make_workload_rejects_unknown_kind():
    with pytest.raises(ValueError):
        wl.make_workload("uniform", rate=1.0, duration=1.0, seed=0,
                         vocab_size=10)


def test_trace_round_trip(tmp_path):
    items = wl.make_workload("mmpp", rate=0.5, duration=40.0, seed=5,
                             vocab_size=100)
    path = str(tmp_path / "trace.jsonl")
    wl.save_trace(path, items)
    assert wl.load_trace(path) == sorted(items, key=lambda it: it.t)
    # and the trace kind replays the file verbatim
    again = wl.make_workload("trace", rate=0.0, duration=0.0, seed=0,
                             vocab_size=0, trace_path=path)
    assert again == wl.load_trace(path)


def test_offered_load():
    items = [wl.WorkloadItem(1.0, (1, 2), 3), wl.WorkloadItem(2.0, (1,), 4)]
    # declared duration divides the real span, not the last-arrival time
    assert wl.offered_load(items, 5.0) == pytest.approx(10 / 5.0)
    # no duration (trace replay): last arrival stands in
    assert wl.offered_load(items) == pytest.approx(10 / 2.0)
    assert wl.offered_load([]) == 0.0


def test_virtual_clock_skip_never_rewinds():
    c = wl.VirtualClock()
    c.tick(); c.tick()
    c.skip_to(1.0)        # behind now: no-op
    assert c.now == 2.0
    c.skip_to(10.0)
    assert c.now == 10.0


# ----------------------------------------------------------------- metrics


def test_percentile_nearest_rank():
    xs = list(range(1, 101))           # 1..100
    assert sm.percentile(xs, 50) == 50
    assert sm.percentile(xs, 95) == 95
    assert sm.percentile(xs, 99) == 99
    assert sm.percentile([7.0], 99) == 7.0
    assert math.isnan(sm.percentile([], 50))


def _req(t_submit, t_admit, t_done, n_out):
    r = Request(0, [1], max_new_tokens=n_out)
    r.output = list(range(n_out))
    r.done = True
    r.t_submit, r.t_admit, r.t_first, r.t_done = (t_submit, t_admit,
                                                  t_admit, t_done)
    return r


def test_request_metrics_definitions():
    m = sm.request_metrics(_req(t_submit=2, t_admit=5, t_done=12, n_out=8))
    assert m["queue_wait"] == 3            # 5 - 2
    assert m["ttft"] == 4                  # 5 - 2 + 1 (prefill tick counts)
    assert m["tpot"] == pytest.approx(7 / 7)   # (12-5) / (8-1)
    # one-token request: no decode phase, no TPOT sample
    m1 = sm.request_metrics(_req(0, 0, 0, n_out=1))
    assert "tpot" not in m1
    # unfinished request contributes nothing
    r = Request(0, [1])
    assert sm.request_metrics(r) is None


def test_aggregate_scaling_and_counts():
    reqs = [_req(0, 0, 6, 4), _req(1, 3, 9, 4), Request(9, [1])]
    agg = sm.aggregate(reqs, ticks=10, util_history=[0.5, 1.0],
                       tick_seconds=2.0)
    assert agg["completed"] == 2 and agg["submitted"] == 3
    assert agg["tokens"] == 8
    assert agg["queue_wait"]["p99"] == 2 * 2.0     # ticks * tick_seconds
    assert agg["tokens_per_sec"] == pytest.approx(8 / 20.0)
    assert agg["mean_util"] == pytest.approx(0.75)
