"""benchmarks/serving_load.py: determinism contract + document schema."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import serving_load as sl  # noqa: E402
from repro.configs import SERVING_LOAD_SWEEP, ServingLoadCell  # noqa: E402


def test_sweep_spans_three_families():
    assert {c.family for c in SERVING_LOAD_SWEEP} == {"dense", "moe", "rwkv"}
    assert len({c.arch for c in SERVING_LOAD_SWEEP}) >= 3


@pytest.mark.slow
def test_cell_metrics_identical_across_runs():
    """The acceptance contract: two same-seed virtual-clock runs of a cell
    produce byte-identical metrics (fresh engine each time)."""
    cell = ServingLoadCell("rwkv6-1.6b", "rwkv", 2, 0.5)
    a = sl.run_cell(cell, duration=12.0, seed=3)
    b = sl.run_cell(cell, duration=12.0, seed=3)
    assert a["metrics"] == b["metrics"]
    # a different seed must actually change the workload
    c = sl.run_cell(cell, duration=12.0, seed=4)
    assert c["metrics"] != a["metrics"]


@pytest.mark.slow
def test_sweep_document_schema(tmp_path):
    """A trimmed sweep (one cell per family) produces the BENCH_serving
    document shape the perf trajectory consumes."""
    seen, cells = set(), []
    for c in SERVING_LOAD_SWEEP:
        if c.family not in seen:
            seen.add(c.family)
            cells.append(c)
    doc = sl.sweep(fast=True, cells=cells, duration=10.0)
    assert doc["schema"] == sl.SCHEMA
    assert doc["families"] == ["dense", "moe", "rwkv"]
    assert len(doc["cells"]) == 3
    for c in doc["cells"]:
        m = c["metrics"]
        assert m["completed"] == m["submitted"] > 0
        for key in ("ttft", "tpot", "queue_wait"):
            assert {"p50", "p95", "p99", "mean", "n"} <= set(m[key])
        assert m["tokens_per_sec"] > 0
        # mean_util is the TRUE ratio and may exceed 1.0 on instant-admit
        # ticks (several one-token requests through one slot in one tick)
        assert m["mean_util"] > 0.0
        assert c["wall"]["seconds"] > 0
    # round-trips through the writer, and the deterministic view drops wall
    sl.write(doc, str(tmp_path / "BENCH_serving.json"))
    det = sl.deterministic_view(doc)
    assert "wall" not in det["cells"][0] and "metrics" in det["cells"][0]
