"""benchmarks/serving_load.py: determinism contract + document schema."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import serving_load as sl  # noqa: E402
from repro.configs import SERVING_LOAD_SWEEP, ServingLoadCell  # noqa: E402


def test_sweep_spans_three_families():
    assert {c.family for c in SERVING_LOAD_SWEEP} == {"dense", "moe", "rwkv"}
    assert len({c.arch for c in SERVING_LOAD_SWEEP}) >= 3


def test_sweep_cell_names_unique_and_dimensions_present():
    names = [c.name for c in SERVING_LOAD_SWEEP]
    assert len(names) == len(set(names))
    # the new committed benchmark dimensions: prompt distributions and the
    # overload scheduling scenario ride along without renaming base cells
    assert {c.prompt_dist for c in SERVING_LOAD_SWEEP} >= \
        {"uniform", "fixed", "lognormal", "bimodal"}
    overload = [c for c in SERVING_LOAD_SWEEP if c.deadline_slack is not None]
    assert {(c.policy, c.preempt) for c in overload} == \
        {("fcfs", False), ("edf", False), ("edf", True)}
    base = [c for c in SERVING_LOAD_SWEEP
            if c.policy == "fcfs" and not c.preempt
            and c.prompt_dist == "uniform" and c.heavy_decode is None
            and c.cache_layout == "dense"]
    assert all("/" not in c.name.replace(f"{c.arch}/", "", 1).replace(
        f"b{c.max_batch}/", "", 1) for c in base)   # historical names intact
    # PR 7: paged cells ride along, tagged by layout, never renaming the
    # dense twins they are compared against
    paged = [c for c in SERVING_LOAD_SWEEP if c.cache_layout != "dense"]
    assert paged and all(c.name.endswith("/paged16") for c in paged)


def test_smoke_registry_guard_detects_drift(monkeypatch):
    """The --smoke CI guard passes on the real registry and fails loudly
    when the scheduler registry and the CLI --policy choices diverge."""
    from repro.serving import scheduler as sched_mod

    sl._check_policy_registry()   # current surfaces agree
    monkeypatch.setitem(sched_mod.SCHEDULERS, "fake", sched_mod.FCFS)
    with pytest.raises(RuntimeError, match="drifted"):
        sl._check_policy_registry()


def test_committed_cells_embed_plans_and_auto_beats_default():
    """Plan-centric acceptance: every committed BENCH cell embeds a
    valid *resolved* plan dict, and the autotuned overload cell beats the
    hand-picked default design point on the same workload."""
    import json
    from pathlib import Path

    from repro.plan import io as plan_io

    doc = json.loads((Path(__file__).resolve().parent.parent /
                      "BENCH_serving.json").read_text())
    for c in doc["cells"]:
        plan = plan_io.from_dict(c["plan"])
        plan.validate()
        assert plan.buckets is not None        # resolved, not defaulted
        assert plan.arch == c["arch"]
        assert plan.max_batch == c["max_batch"]
    auto = [c for c in doc["cells"] if c["name"].endswith("/auto")]
    assert auto, "the sweep must record the autotuned overload cell"
    fcfs = next(c for c in doc["cells"]
                if c["name"] == "rwkv6-1.6b/b4/r0.8/heavy")
    for c in auto:
        assert c["plan"]["provenance"]["autotune"]["probes"]
        assert c["metrics"]["ttft"]["p95"] < fcfs["metrics"]["ttft"]["p95"]
        assert (c["metrics"]["slo"]["attainment"]
                > fcfs["metrics"]["slo"]["attainment"])


def test_committed_drift_cells_show_replan_beating_stale():
    """Observability acceptance: the committed drifting-workload cells
    embed valid plans; the replan's provenance records the profile
    fitted from the observed trace plus a trace summary, and the
    re-autotuned plan beats the stale calm-tuned plan on SLO
    attainment on the same drifted workload."""
    import json
    from pathlib import Path

    doc = json.loads((Path(__file__).resolve().parent.parent /
                      "BENCH_serving.json").read_text())
    cells = {c["name"]: c for c in doc["cells"]}
    stale = next(c for n, c in cells.items() if n.endswith("/drift-stale"))
    replan = next(c for n, c in cells.items()
                  if n.endswith("/drift-replan"))
    # the stale plan was tuned on calm deadline-free traffic: no deadline
    # policy, and its probe workload is not the drifted one
    assert stale["plan"]["policy"] == "fcfs"
    prov = replan["plan"]["provenance"]
    assert prov["autotune"]["probes"]
    obs = prov["observed_traffic"]
    assert obs["trace_summary"]["submits"] > 0
    assert obs["trace_summary"]["with_deadline"] > 0
    assert obs["fitted_profile"]["rate"] > 0
    # the drift the replan must react to: more capacity than the stale plan
    assert replan["plan"]["max_batch"] > stale["plan"]["max_batch"]
    assert (replan["metrics"]["slo"]["attainment"]
            > stale["metrics"]["slo"]["attainment"])


def test_committed_paged_twin_bit_exact_and_capacity_rises():
    """PR 7 acceptance, from the committed file alone: the paged twin of a
    base-grid cell carries a byte-identical metrics block (the block-table
    backing store changed no schedule), and the paged b8 capacity cells
    show heavy-tail workloads admitted with less queueing than the b4
    dense baseline on the same prompt distribution (virtual-clock
    schedules depend only on scheduling parameters, so the cells are
    directly comparable across archs)."""
    import json
    from pathlib import Path

    doc = json.loads((Path(__file__).resolve().parent.parent /
                      "BENCH_serving.json").read_text())
    cells = {c["name"]: c for c in doc["cells"]}
    dense = cells["qwen2.5-14b/b4/r1"]
    paged = cells["qwen2.5-14b/b4/r1/paged16"]
    assert paged["metrics"] == dense["metrics"]
    assert paged["plan"]["cache_layout"] == "paged:16"
    assert dense["plan"].get("cache_layout", "dense") == "dense"
    for dist in ("lognormal", "bimodal"):
        big = cells[f"qwen2.5-14b/b8/r1/{dist}/paged16"]
        small = cells[f"rwkv6-1.6b/b4/r1/{dist}"]
        assert big["metrics"]["completed"] == big["metrics"]["submitted"]
        assert (big["metrics"]["queue_wait"]["p95"]
                < small["metrics"]["queue_wait"]["p95"])


def test_committed_fragmentation_trajectory_contracts():
    """The committed BENCH_fragmentation.json memory trajectories uphold
    the PR 7 contracts offline: identical tokens-in-flight under both
    layouts (the schedule is layout-blind), paged bytes-resident never
    above dense at any sample, and the recorded peaks/savings consistent
    with their own trajectories."""
    import json
    from pathlib import Path

    from benchmarks import fig4_fragmentation as f4

    doc = json.loads((Path(__file__).resolve().parent.parent /
                      "BENCH_fragmentation.json").read_text())
    assert doc["schema"] == f4.SCHEMA
    cells = doc["cells"]
    assert len(cells) >= 4
    for c in cells:
        d, p = c["dense"], c["paged"]
        assert d["tokens_in_flight"] == p["tokens_in_flight"]
        assert all(pb <= db for pb, db in
                   zip(p["bytes_resident"], d["bytes_resident"]))
        assert d["peak_bytes"] == max(d["bytes_resident"])
        assert p["peak_bytes"] == max(p["bytes_resident"])
        assert c["peak_saving_bytes"] == d["peak_bytes"] - p["peak_bytes"]
    # the attention-bearing heavy-tail cells actually save at peak; the
    # pure-RNN cells tie exactly (recurrent state is never paged)
    saved = {c["name"]: c["peak_saving_bytes"] for c in cells}
    assert all(v > 0 for n, v in saved.items() if n.startswith("qwen"))
    assert all(v == 0 for n, v in saved.items() if n.startswith("rwkv"))


@pytest.mark.slow
def test_paged_rerun_reproduces_committed_dense_metrics():
    """Live half of the bit-exactness contract: re-running the committed
    dense base cell with a paged:16 backing store reproduces the committed
    dense metrics block byte-for-byte."""
    import dataclasses
    import json
    from pathlib import Path

    doc = json.loads((Path(__file__).resolve().parent.parent /
                      "BENCH_serving.json").read_text())
    committed = {c["name"]: c for c in doc["cells"]}
    dense_cell = next(c for c in SERVING_LOAD_SWEEP
                      if c.name == "qwen2.5-14b/b4/r1")
    paged_cell = ServingLoadCell(
        dense_cell.arch, dense_cell.family, dense_cell.max_batch,
        dense_cell.rate,
        plan=dataclasses.replace(dense_cell.plan, cache_layout="paged:16"))
    fresh = sl.run_cell(paged_cell, duration=doc["duration"],
                        seed=doc["seed"])
    assert fresh["metrics"] == committed["qwen2.5-14b/b4/r1"]["metrics"]


@pytest.mark.slow
def test_cell_metrics_identical_across_runs():
    """The acceptance contract: two same-seed virtual-clock runs of a cell
    produce byte-identical metrics (fresh engine each time)."""
    cell = ServingLoadCell("rwkv6-1.6b", "rwkv", 2, 0.5)
    a = sl.run_cell(cell, duration=12.0, seed=3)
    b = sl.run_cell(cell, duration=12.0, seed=3)
    assert a["metrics"] == b["metrics"]
    # a different seed must actually change the workload
    c = sl.run_cell(cell, duration=12.0, seed=4)
    assert c["metrics"] != a["metrics"]


@pytest.mark.slow
def test_refactor_matches_committed_trajectory():
    """The multi-layer refactor contract: a fresh run of a base-grid cell
    reproduces the committed BENCH_serving.json metrics block byte-for-
    byte (scheduler extraction + slot-state manager + overlapped prefill
    changed no FCFS schedule)."""
    import json
    from pathlib import Path

    bench = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
    doc = json.loads(bench.read_text())
    committed = {c["name"]: c for c in doc["cells"]}
    cell = next(c for c in SERVING_LOAD_SWEEP
                if c.name == "rwkv6-1.6b/b2/r1")
    fresh = sl.run_cell(cell, duration=doc["duration"], seed=doc["seed"])
    assert fresh["metrics"] == committed[cell.name]["metrics"]


@pytest.mark.slow
def test_overload_edf_improves_p95_ttft():
    """The overload acceptance: under the committed overload scenario the
    EDF cells beat FCFS on p95 TTFT, and preemptive EDF actually
    preempts, with every preempted request still completing."""
    cells = {(c.policy, c.preempt): c for c in SERVING_LOAD_SWEEP
             if c.deadline_slack is not None}
    built = sl._build("rwkv6-1.6b", True)
    out = {k: sl.run_cell(c, seed=0, _built=built)
           for k, c in cells.items()}
    fcfs = out[("fcfs", False)]["metrics"]
    for key in (("edf", False), ("edf", True)):
        m = out[key]["metrics"]
        assert m["ttft"]["p95"] < fcfs["ttft"]["p95"]
        assert m["completed"] == m["submitted"]
    assert out[("edf", True)]["sched"]["preemptions"] > 0
    assert out[("edf", True)]["sched"]["resumes"] == \
        out[("edf", True)]["sched"]["preemptions"]


@pytest.mark.slow
def test_sweep_document_schema(tmp_path):
    """A trimmed sweep (one cell per family) produces the BENCH_serving
    document shape the perf trajectory consumes."""
    seen, cells = set(), []
    for c in SERVING_LOAD_SWEEP:
        if c.family not in seen:
            seen.add(c.family)
            cells.append(c)
    doc = sl.sweep(fast=True, cells=cells, duration=10.0)
    assert doc["schema"] == sl.SCHEMA
    assert doc["families"] == ["dense", "moe", "rwkv"]
    assert len(doc["cells"]) == 3
    for c in doc["cells"]:
        m = c["metrics"]
        assert m["completed"] == m["submitted"] > 0
        for key in ("ttft", "tpot", "queue_wait"):
            assert {"p50", "p95", "p99", "mean", "n"} <= set(m[key])
        assert m["tokens_per_sec"] > 0
        # mean_util is the TRUE ratio and may exceed 1.0 on instant-admit
        # ticks (several one-token requests through one slot in one tick)
        assert m["mean_util"] > 0.0
        assert c["wall"]["seconds"] > 0
    # round-trips through the writer, and the deterministic view drops wall
    sl.write(doc, str(tmp_path / "BENCH_serving.json"))
    det = sl.deterministic_view(doc)
    assert "wall" not in det["cells"][0] and "metrics" in det["cells"][0]
