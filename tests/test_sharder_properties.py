"""Property-based Sharder tests (hypothesis; replay stub when absent).

Randomized meshes / rules / tensor shapes pin the resolution contract of
``repro.dist.sharding.Sharder`` (see its module docstring):

* spec axes honored — every mesh axis a spec assigns to a tensor dim comes
  from that dim's logical-axis rule, in rule order;
* the divisibility fallback never over-shards — an assigned shard count
  always divides the dimension;
* one mesh axis is never assigned to two dims of the same tensor;
* the mesh-less Sharder is a strict no-op.

``tests/conftest.py`` installs ``repro._hypothesis_stub`` when the real
package is missing, so this file runs the genuine shrinking search on CI
(which installs hypothesis) and a deterministic replay sweep otherwise.
"""

import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist.sharding import Sharder

AXIS_SIZES = (1, 2, 3, 4, 8, 16)
MESH_AXES = ("pod", "data", "model")
RULES = ((), ("model",), ("data",), ("pod", "data"), ("data", "model"),
         ("pod", "data", "model"), ("model", "data"))


class FakeMesh:
    """Just enough Mesh surface for rule resolution (as test_sharding.py)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _sharder(rules, sizes):
    s = Sharder.__new__(Sharder)
    s.mesh = FakeMesh(tuple(zip(MESH_AXES, sizes)))
    s.rules = dict(rules)
    return s


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@settings(max_examples=120, deadline=None)
@given(pod=st.sampled_from(AXIS_SIZES), data=st.sampled_from(AXIS_SIZES),
       model=st.sampled_from(AXIS_SIZES), rule=st.sampled_from(RULES),
       dim=st.integers(min_value=1, max_value=96))
def test_resolve_is_divisible_rule_prefix(pod, data, model, rule, dim):
    """resolve() returns a prefix of the rule whose shard count divides the
    dim — the fallback drops trailing axes, never over-shards, never
    invents axes."""
    s = _sharder({"x": rule}, (pod, data, model))
    r = s.resolve("x", dim)
    present = tuple(a for a in rule if a in s.mesh.shape)
    if r is None:
        # fallback exhausted: no non-empty prefix of the rule divides dim
        assert all(dim % _prod(s.mesh, present[:k])
                   for k in range(1, len(present) + 1)) or not present
    else:
        assert r == present[:len(r)]          # prefix, in rule order
        assert dim % _prod(s.mesh, r) == 0    # never over-shards


@settings(max_examples=120, deadline=None)
@given(pod=st.sampled_from(AXIS_SIZES), data=st.sampled_from(AXIS_SIZES),
       model=st.sampled_from(AXIS_SIZES),
       r0=st.sampled_from(RULES), r1=st.sampled_from(RULES),
       r2=st.sampled_from(RULES),
       d0=st.integers(min_value=1, max_value=64),
       d1=st.integers(min_value=1, max_value=64),
       d2=st.integers(min_value=1, max_value=64))
def test_spec_no_axis_reuse_and_axes_honored(pod, data, model, r0, r1, r2,
                                             d0, d1, d2):
    rules = {"a0": r0, "a1": r1, "a2": r2}
    s = _sharder(rules, (pod, data, model))
    shape = (d0, d1, d2)
    spec = s.spec(("a0", "a1", "a2"), shape)
    used = []
    for entry, logical, dim in zip(spec, ("a0", "a1", "a2"), shape):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        used.extend(axes)
        # honored: only axes the logical rule names, and divisibility holds
        assert set(axes) <= set(rules[logical])
        assert dim % _prod(s.mesh, axes) == 0
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


@settings(max_examples=60, deadline=None)
@given(rule=st.sampled_from(RULES),
       dim=st.integers(min_value=1, max_value=64),
       with_rules=st.booleans())
def test_meshless_sharder_is_noop(rule, dim, with_rules):
    s = Sharder(None, {"x": rule} if with_rules else {})
    assert s.resolve("x", dim) is None
    assert s.sharding(("x",), (dim,)) is None
    x = jnp.ones((dim,))
    assert s.constrain(x, "x") is x
